"""Parallel, resumable campaign engine: determinism, parity, resume, batching."""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import CrashTester, PersistPlan
from repro.core.campaign_store import CampaignStore, CampaignStoreError
from repro.core.cache_sim import (
    CacheConfig,
    Flush,
    RegionEvents,
    Sweep,
    resolve_live_values,
    resolve_nvm_image,
    resolve_window_images,
    simulate_window,
)
from repro.hpc.suite import ci_app, default_cache


@pytest.fixture(scope="module")
def mg_setup():
    app = ci_app("mg")
    return app, default_cache(app)


def _dicts(campaign):
    return [dataclasses.asdict(r) for r in campaign.records]


# ------------------------------------------------------------------ determinism
def test_campaign_deterministic(mg_setup):
    app, cache = mg_setup
    a = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(8)
    b = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(8)
    assert _dicts(a) == _dicts(b)
    assert a.window_write_stats == b.window_write_stats


def test_plan_bounds_match_simulated_window(mg_setup):
    """The planner's arithmetic window clock must agree with the simulator's
    (this is what lets planning pre-draw crash times without simulating)."""
    app, cache = mg_setup
    tester = CrashTester(app, PersistPlan.none(), cache, seed=0)
    for crash_iter in {0, 1, tester.golden_iters // 2, tester.golden_iters - 1}:
        t_lo, t_end = tester._window_bounds(crash_iter)
        trace, _, span_start = tester._simulate_crash_window(crash_iter)
        assert (t_lo, t_end) == (span_start, trace.t_end), crash_iter


@pytest.mark.slow
def test_parallel_matches_serial(mg_setup):
    """n_workers=1 is the serial engine; n_workers=4 must match it exactly
    (same seed -> same S1-S4 outcomes and per-object inconsistency rates)."""
    app, cache = mg_setup
    serial = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(12)
    par = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        12, n_workers=4
    )
    assert _dicts(serial) == _dicts(par)
    assert serial.class_fractions() == par.class_fractions()
    assert serial.window_write_stats == par.window_write_stats


def test_unpicklable_app_falls_back_to_serial(mg_setup):
    app, cache = mg_setup
    serial = CrashTester(app, PersistPlan.none(), cache, seed=5).run_campaign(6)
    broken = ci_app("mg")
    broken.unpicklable = lambda: None  # lambdas cannot cross a process boundary
    with pytest.warns(RuntimeWarning, match="not picklable"):
        camp = CrashTester(broken, PersistPlan.none(), cache, seed=5).run_campaign(
            6, n_workers=4
        )
    assert _dicts(camp) == _dicts(serial)


# ----------------------------------------------------------------------- store
def test_resume_completes_truncated_store(mg_setup, tmp_path):
    app, cache = mg_setup
    path = str(tmp_path / "campaign.jsonl")
    full = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        12, store_path=path
    )
    lines = open(path).read().splitlines()
    n_shards = len(lines) - 1  # minus header
    assert n_shards >= 2

    # kill mid-run: keep the header + 2 complete shards + one torn line
    with open(path, "w") as f:
        f.write("\n".join(lines[:3]) + "\n" + lines[3][: len(lines[3]) // 2])

    # count at _prepare_window_items: once per executed shard on both the
    # per-shard and the chunked (lane-batched) vec paths
    executed = []
    orig = CrashTester._prepare_window_items

    def counting(self, crash_iter, tests):
        executed.append(crash_iter)
        return orig(self, crash_iter, tests)

    CrashTester._prepare_window_items = counting
    try:
        resumed = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
            12, store_path=path
        )
    finally:
        CrashTester._prepare_window_items = orig

    assert _dicts(resumed) == _dicts(full)
    # only the missing shards ran: 2 complete shards came from the store, the
    # torn third line was discarded and re-executed
    assert len(set(executed)) == n_shards - 2

    # a completed store resumes to the same result with zero shards executed
    executed.clear()
    CrashTester._prepare_window_items = counting
    try:
        again = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
            12, store_path=path
        )
    finally:
        CrashTester._prepare_window_items = orig
    assert _dicts(again) == _dicts(full)
    assert executed == []


def test_resume_with_flush_plan(mg_setup, tmp_path):
    """Fingerprints with a non-empty region_freq must survive the JSON
    round-trip (tuples vs lists) and resume cleanly."""
    app, cache = mg_setup
    plan = PersistPlan.at_loop_end(("u",), app)
    path = str(tmp_path / "campaign.jsonl")
    full = CrashTester(app, plan, cache, seed=3).run_campaign(6, store_path=path)
    again = CrashTester(app, plan, cache, seed=3).run_campaign(6, store_path=path)
    assert _dicts(again) == _dicts(full)


def test_store_rejects_same_app_different_config(mg_setup, tmp_path):
    """Two campaigns on the same app *name* but different problem data must
    not share a store (the state digest tells them apart)."""
    app, cache = mg_setup
    path = str(tmp_path / "campaign.jsonl")
    CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        4, store_path=path
    )
    other = ci_app("mg", seed=9)  # same name/sizes, different problem data
    with pytest.raises(CampaignStoreError):
        CrashTester(other, PersistPlan.none(), cache, seed=3).run_campaign(
            4, store_path=path
        )


def test_store_rejects_foreign_campaign(mg_setup, tmp_path):
    app, cache = mg_setup
    path = str(tmp_path / "campaign.jsonl")
    CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        6, store_path=path
    )
    with pytest.raises(CampaignStoreError):
        CrashTester(app, PersistPlan.none(), cache, seed=4).run_campaign(
            6, store_path=path
        )
    with pytest.raises(CampaignStoreError):
        CrashTester(
            app, PersistPlan.at_loop_end(("u",), app), cache, seed=3
        ).run_campaign(6, store_path=path)


def test_store_raises_on_midfile_corruption(mg_setup, tmp_path):
    """The resume-safety argument tolerates exactly one torn *trailing* line
    (the crash signature of an fsynced append).  An undecodable line in the
    middle of the file is corruption: silently skipping it would silently
    drop a completed shard from the resumed campaign."""
    app, cache = mg_setup
    path = str(tmp_path / "campaign.jsonl")
    CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        12, store_path=path
    )
    lines = open(path).read().splitlines()
    assert len(lines) >= 4
    lines[2] = lines[2][: len(lines[2]) // 2]  # torn line with data after it
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(CampaignStoreError, match="mid-file corruption"):
        CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
            12, store_path=path
        )
    with pytest.raises(CampaignStoreError, match="mid-file corruption"):
        CampaignStore(path).completed_shards()


def test_store_tolerates_torn_trailing_line_without_newline(mg_setup, tmp_path):
    """The one corruption a crash *can* produce — a torn final append with
    no terminating newline — still resumes (that shard just re-executes)."""
    import dataclasses as dc

    app, cache = mg_setup
    path = str(tmp_path / "campaign.jsonl")
    full = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        12, store_path=path
    )
    lines = open(path).read().splitlines()
    with open(path, "w") as f:
        f.write("\n".join(lines[:-1]) + "\n" + lines[-1][: len(lines[-1]) // 2])
    resumed = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        12, store_path=path
    )
    assert [dc.asdict(r) for r in resumed.records] == \
           [dc.asdict(r) for r in full.records]


def test_store_rejects_non_object_and_binary_corruption(mg_setup, tmp_path):
    """Corruption beyond torn tails surfaces as CampaignStoreError, never a
    raw AttributeError/UnicodeDecodeError: decodable non-dict lines are
    foreign content, invalid UTF-8 mid-file is corruption — while a torn
    multi-byte character at EOF is just a torn tail and must resume."""
    import dataclasses as dc

    app, cache = mg_setup
    path = str(tmp_path / "campaign.jsonl")
    full = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        8, store_path=path
    )
    lines = open(path).read().splitlines()

    # decodable non-dict line
    with open(path, "w") as f:
        f.write("\n".join([lines[0], "42"] + lines[1:]) + "\n")
    with pytest.raises(CampaignStoreError, match="not a JSON object"):
        CampaignStore(path).completed_shards()

    # invalid UTF-8 mid-file
    with open(path, "wb") as f:
        f.write(lines[0].encode() + b"\n\xff\xfe{broken\n"
                + "\n".join(lines[1:]).encode() + b"\n")
    with pytest.raises(CampaignStoreError, match="mid-file corruption"):
        CampaignStore(path).completed_shards()

    # torn multi-byte character at EOF: tolerated, resumes to the full result
    with open(path, "wb") as f:
        f.write("\n".join(lines).encode() + b"\n"
                + b'{"type": "shard", "torn": "\xe2\x82')  # cut mid-char
    resumed = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        8, store_path=path
    )
    assert [dc.asdict(r) for r in resumed.records] == \
           [dc.asdict(r) for r in full.records]


def test_store_survives_newline_only_tear(mg_setup, tmp_path):
    """A crash can land every byte of an append except the final newline.
    The line is then complete, and the reader accepts it — the next append
    must *terminate* it, not truncate it, or a resume would silently delete
    data it already counted (worst case: the header, bricking the store)."""
    import dataclasses as dc

    app, cache = mg_setup
    path = str(tmp_path / "campaign.jsonl")
    full = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        8, store_path=path
    )
    lines = open(path).read().splitlines()

    # header-only store whose newline was torn off: two back-to-back resumes
    # must both work (run 1 appends shards after the repaired header; run 2
    # must still find the header first)
    with open(path, "w") as f:
        f.write(lines[0])  # no trailing newline
    r1 = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        8, store_path=path
    )
    r2 = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        8, store_path=path
    )
    assert [dc.asdict(r) for r in r1.records] == [dc.asdict(r) for r in full.records]
    assert [dc.asdict(r) for r in r2.records] == [dc.asdict(r) for r in full.records]

    # same tear on a fully-written store: the final (complete) shard line
    # must survive the repair, not be dropped and re-executed
    with open(path, "w") as f:
        f.write("\n".join(lines))  # all lines, trailing newline torn off
    shards_before = CampaignStore(path).completed_shards()
    again = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(
        8, store_path=path
    )
    assert [dc.asdict(r) for r in again.records] == [dc.asdict(r) for r in full.records]
    assert CampaignStore(path).completed_shards().keys() == shards_before.keys()


def test_store_roundtrip_preserves_records(mg_setup, tmp_path):
    app, cache = mg_setup
    path = str(tmp_path / "campaign.jsonl")
    camp = CrashTester(app, PersistPlan.none(), cache, seed=7).run_campaign(
        6, store_path=path
    )
    shards = CampaignStore(path).completed_shards()
    stored = sorted(
        (pair for recs in shards.values() for pair in recs), key=lambda p: p[0]
    )
    assert [dataclasses.asdict(r) for _, r in stored] == _dicts(camp)
    assert os.path.exists(path)


# ------------------------------------------------------------- batch resolution
def _random_window(rng, n_objs=3, n_regions=6, block_bytes=16):
    names = [f"o{i}" for i in range(n_objs)]
    obj_blocks = {o: int(rng.integers(1, 12)) for o in names}
    values = {
        o: rng.standard_normal(obj_blocks[o] * block_bytes // 4).astype(np.float32)
        for o in names
    }
    regions = []
    seq_values = {}
    for seq in range(n_regions):
        events = []
        for o in names:
            if rng.random() < 0.5:
                events.append(Sweep(o, write=False))
        writes = [o for o in names if rng.random() < 0.6] or [names[0]]
        for o in writes:
            events.append(Sweep(o, write=True))
        if rng.random() < 0.3:
            events.append(Flush(str(rng.choice(names))))
        regions.append(RegionEvents(seq=seq, iter_idx=seq // 3, region_idx=seq % 3,
                                    events=tuple(events)))
        seq_values[seq] = {
            o: rng.standard_normal(values[o].size).astype(np.float32) for o in writes
        }
    trace = simulate_window(CacheConfig(capacity_blocks=int(rng.integers(2, 20)),
                                        block_bytes=block_bytes),
                            obj_blocks, regions)
    return trace, values, seq_values, block_bytes


def test_batch_resolution_matches_single_shot():
    """resolve_window_images == per-crash-time single-shot resolution, for
    random event traces, with and without a chronic base image."""
    rng = np.random.default_rng(0)
    for trial in range(8):
        trace, values, seq_values, bb = _random_window(rng)
        if trace.t_end < 2:
            continue
        crash_ts = sorted(rng.integers(0, trace.t_end, size=7).tolist(),
                          key=lambda _: rng.random())  # deliberately unsorted
        chronic = None
        if trial % 2:
            chronic = {o: np.full_like(v, 7.5) for o, v in values.items()}
        nvms, lives = resolve_window_images(
            trace, crash_ts, values, seq_values, bb, chronic_base=chronic
        )
        for ct, nvm, live in zip(crash_ts, nvms, lives):
            ref_nvm = resolve_nvm_image(trace, ct, values, seq_values, bb,
                                        chronic_base=chronic)
            ref_live = resolve_live_values(trace, ct, values, seq_values, bb)
            for o in values:
                np.testing.assert_array_equal(nvm[o], ref_nvm[o], err_msg=f"nvm {o} t={ct}")
                np.testing.assert_array_equal(live[o], ref_live[o], err_msg=f"live {o} t={ct}")
