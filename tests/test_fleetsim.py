"""Fleet serving-under-failure simulator: conservation laws, seeded
determinism, limit-case reductions, and the differential against the
single-job ``sysim`` oracle.

The fleet simulator is a seeded DES, so its invariants have exact oracles:
every request is served, dropped, or in flight — never lost to bookkeeping;
replica-seconds partition exactly into up/checkpoint/down; identical seeds
reproduce byte-identical results; and with one replica and no traffic the
availability accounting must reduce to ``sysim``'s single-job work fraction.
"""
import dataclasses
import json

import pytest

from repro.core.efficiency import SystemConfig
from repro.core.fleetsim import (
    ArrivalProcess,
    FleetConfig,
    FleetResult,
    ServiceModel,
    fleet_frontier,
    simulate_fleet,
)
from repro.core.sysim import (
    POLICIES,
    PoissonTrace,
    RecomputeProfile,
    WeibullTrace,
    simulate_policy,
)

PROFILE = RecomputeProfile.from_fractions(
    "decode", {"S1": 0.75, "S2": 0.15, "S3": 0.05, "S4": 0.05},
    extra_iters_hist=((2, 4), (9, 1)),
)

SERVE_SYS = SystemConfig(mtbf=1800.0, t_chk=20.0, nvm_restore_time=2.0)


def _cfg(**over) -> FleetConfig:
    base = dict(
        n_replicas=3,
        arrival=ArrivalProcess(rate=3.0, amplitude=0.25),
        service=ServiceModel(mean_s=0.4, sigma=0.5, prefill_s=0.8),
        trace=PoissonTrace(mtbf=600.0),
        system=SERVE_SYS,
        slo_latency=1.5,
        queue_cap=32,
        horizon=1800.0,
        seed=0,
    )
    base.update(over)
    return FleetConfig(**base)


def _prof_for(policy):
    return PROFILE if policy in ("easycrash", "hybrid") else None


# ----------------------------------------------- invariants at fixed seeds
# (the hypothesis-driven generalizations live in
# tests/test_fleetsim_properties.py, which skips when hypothesis is absent)
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_request_conservation_and_time_partition(policy, seed):
    """arrived == served + dropped + in-flight, exactly, for every policy;
    and replica-seconds partition into up/checkpoint/down."""
    cfg = _cfg(
        trace=PoissonTrace(mtbf=300.0),
        queue_cap=8,
        horizon=900.0,
        t_s=0.05,
        seed=seed,
    )
    r = simulate_fleet(policy, cfg, _prof_for(policy))
    assert r.arrived == r.served + r.dropped + r.in_flight
    assert r.dropped_down <= r.dropped
    assert sum(r.breakdown.values()) == pytest.approx(
        cfg.n_replicas * cfg.horizon, abs=1e-6
    )
    assert 0.0 <= r.availability <= 1.0
    assert 0.0 <= r.slo_violation_frac <= 1.0
    if r.served:
        assert r.latency_p50 <= r.latency_p95 <= r.latency_p99 <= r.latency_max


@pytest.mark.parametrize("policy", POLICIES)
def test_identical_seeds_are_byte_identical(policy):
    cfg = _cfg(seed=42, horizon=600.0)
    a = simulate_fleet(policy, cfg, _prof_for(policy))
    b = simulate_fleet(policy, cfg, _prof_for(policy))
    assert a == b
    assert json.dumps(a.payload(), sort_keys=True) == \
        json.dumps(b.payload(), sort_keys=True)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)


def test_different_seed_changes_the_tape():
    a = simulate_fleet("hybrid", _cfg(seed=1), PROFILE)
    b = simulate_fleet("hybrid", _cfg(seed=2), PROFILE)
    assert a.arrived != b.arrived or a.latency_mean != b.latency_mean


# ------------------------------------------------- monotonicity + limit cases
@pytest.mark.parametrize("policy", POLICIES)
def test_goodput_monotone_as_failures_vanish(policy):
    """Failure rate -> 0 can only help: the offered tape is drawn from
    streams independent of the failure trace, so served counts at a quiet
    MTBF dominate served counts at a harsh one (checked across seeds with a
    harsh/quiet gap wide enough that the ordering is not a coin flip)."""
    for seed in (0, 1, 2):
        served = []
        for mtbf in (200.0, 2000.0, 1e12):
            cfg = _cfg(
                trace=PoissonTrace(mtbf=mtbf),
                arrival=ArrivalProcess(rate=4.0, amplitude=0.3),
                horizon=3600.0,
                seed=seed,
            )
            r = simulate_fleet(policy, cfg, _prof_for(policy))
            served.append(r.served)
        assert served[0] <= served[1] <= served[2], (policy, seed, served)


def test_no_failures_no_recoveries():
    cfg = _cfg(trace=PoissonTrace(mtbf=1e15), horizon=1200.0)
    r = simulate_fleet("hybrid", cfg, PROFILE)
    assert r.n_failures == 0
    assert r.n_nvm_recoveries == r.n_fallbacks == r.n_cold_restarts == 0
    assert r.dropped_down == 0
    # quiet fleet: hybrid still checkpoints on its stretched interval
    assert r.breakdown.get("down", 0.0) == 0.0


def test_offered_load_is_trace_invariant():
    """The same seed offers the same request tape no matter the failure
    trace or policy — the property the policy frontier depends on."""
    base = simulate_fleet("none", _cfg(trace=PoissonTrace(1e12)))
    for policy in POLICIES:
        for mtbf in (300.0, 3000.0):
            r = simulate_fleet(policy, _cfg(trace=PoissonTrace(mtbf)),
                               _prof_for(policy))
            assert r.arrived == base.arrived


def test_zero_rate_serves_nothing():
    r = simulate_fleet("checkpoint", _cfg(arrival=ArrivalProcess(rate=0.0)))
    assert r.arrived == r.served == r.dropped == r.in_flight == 0
    assert r.latency_p99 == 0.0  # strict-JSON-safe sentinel, not NaN
    assert r.n_checkpoints > 0   # idle replicas still checkpoint on schedule


def test_warm_beats_cold_recovery_on_tail_latency():
    """The KV-cache story in one assertion: a perfect NVM profile (always
    warm) yields a better tail than the same fleet restoring cold, because
    cold recovery re-runs prefill for every interrupted session."""
    warm_prof = RecomputeProfile.from_fractions("p", {"S1": 1.0})
    cfg = _cfg(
        trace=PoissonTrace(mtbf=240.0),
        arrival=ArrivalProcess(rate=4.5, amplitude=0.0),
        service=ServiceModel(mean_s=0.4, sigma=0.5, prefill_s=3.0),
        horizon=3600.0,
        seed=5,
    )
    warm = simulate_fleet("easycrash", cfg, warm_prof)
    cold = simulate_fleet("checkpoint", cfg)
    assert warm.n_nvm_recoveries > 0
    assert warm.latency_p99 < cold.latency_p99
    assert warm.goodput >= cold.goodput


# ------------------------------------------------------- reduction to sysim
@pytest.mark.slow
@pytest.mark.parametrize("policy", POLICIES)
def test_reduction_to_sysim_availability(policy):
    """One replica, no traffic: the fleet's availability must match the
    single-job simulator's work-time fraction for every policy (same trace
    distribution, same recovery semantics, independent RNG streams — so the
    comparison is statistical, over ~2000 failure events)."""
    horizon = 120 * 24 * 3600.0
    system = SystemConfig(mtbf=3600.0, t_chk=60.0, nvm_restore_time=5.0)
    prof = _prof_for(policy)
    cfg = FleetConfig(
        n_replicas=1,
        arrival=ArrivalProcess(rate=0.0),
        trace=PoissonTrace(mtbf=3600.0),
        system=system,
        horizon=horizon,
        t_iter=1.0,
        seed=3,
    )
    fleet = simulate_fleet(policy, cfg, prof)
    job = simulate_policy(policy, system, PoissonTrace(3600.0), prof,
                          n_failures=0, horizon=horizon, t_iter=1.0, seed=3)
    job_work_frac = job.breakdown.get("work", 0.0) / job.total_time
    assert fleet.availability == pytest.approx(job_work_frac, abs=0.02), (
        policy, fleet.availability, job_work_frac
    )
    # both sides actually saw a failure-rich tape
    assert fleet.n_failures > 1000 and job.n_failures > 1000


# ------------------------------------------------------------- config + API
def test_config_validation():
    with pytest.raises(ValueError, match="n_replicas"):
        _cfg(n_replicas=0)
    with pytest.raises(ValueError, match="rate"):
        ArrivalProcess(rate=-1.0)
    with pytest.raises(ValueError, match="amplitude"):
        ArrivalProcess(rate=1.0, amplitude=1.0)
    with pytest.raises(ValueError, match="mean_s"):
        ServiceModel(mean_s=0.0)
    with pytest.raises(ValueError, match="t_s"):
        _cfg(t_s=1.0)
    with pytest.raises(ValueError, match="queue_cap"):
        _cfg(queue_cap=0)
    with pytest.raises(ValueError, match="unknown policy"):
        simulate_fleet("raid", _cfg())
    with pytest.raises(ValueError, match="RecomputeProfile"):
        simulate_fleet("hybrid", _cfg())


def test_config_spec_fingerprint_round_trip():
    """spec() is JSON-round-trip safe and the fingerprint is stable under
    round-trip but sensitive to any identity field (mirrors WorkflowConfig)."""
    cfg = _cfg(trace=WeibullTrace(mtbf=900.0, shape=0.7))
    spec = json.loads(json.dumps(cfg.spec()))
    assert spec == cfg.spec()
    assert cfg.fingerprint() == cfg.replace().fingerprint()
    assert cfg.fingerprint() != cfg.replace(seed=cfg.seed + 1).fingerprint()
    assert cfg.fingerprint() != cfg.replace(n_replicas=5).fingerprint()
    # a field-for-field rebuild of the same values fingerprints identically
    rebuilt = FleetConfig(
        **{f.name: getattr(cfg, f.name) for f in dataclasses.fields(cfg)}
    )
    assert cfg.fingerprint() == rebuilt.fingerprint()


def test_diurnal_modulation_shapes_the_offered_load():
    """With amplitude > 0 and the period matched to the horizon, the peak
    half of the tape must carry more arrivals than the trough half."""
    cfg = _cfg(
        arrival=ArrivalProcess(rate=3.0, amplitude=0.8, period=3600.0),
        trace=PoissonTrace(1e12),
        horizon=3600.0,
        seed=9,
    )
    # first half of the sine period is the peak (sin >= 0), second the trough
    rng_probe = ArrivalProcess(rate=3.0, amplitude=0.8, period=3600.0)
    assert rng_probe.rate_at(900.0) > rng_probe.rate_at(2700.0)
    r = simulate_fleet("none", cfg)
    assert r.arrived > 0
    assert r.offered_rate == pytest.approx(r.arrived / cfg.horizon)


def test_frontier_document_is_strict_json():
    cfg = _cfg(horizon=600.0)
    doc = fleet_frontier(cfg, PROFILE)
    round_trip = json.loads(json.dumps(doc, allow_nan=False))
    assert set(round_trip["policies"]) == set(POLICIES)
    assert round_trip["fingerprint"] == cfg.fingerprint()
    for p in round_trip["policies"].values():
        assert p["arrived"] == p["served"] + p["dropped"] + p["in_flight"]


def test_result_is_frozen():
    r = simulate_fleet("none", _cfg(horizon=300.0))
    assert isinstance(r, FleetResult)
    with pytest.raises(dataclasses.FrozenInstanceError):
        r.goodput = 1.0


def test_persist_tax_slows_easycrash_service():
    """t_s inflates EasyCrash service times (capacity charge): with a busy
    fleet and no failures, mean latency at t_s=0.3 exceeds t_s=0."""
    quiet = PoissonTrace(1e15)
    cfg0 = _cfg(trace=quiet, t_s=0.0, horizon=1200.0,
                arrival=ArrivalProcess(rate=5.0))
    cfg1 = cfg0.replace(t_s=0.3)
    r0 = simulate_fleet("easycrash", cfg0, PROFILE)
    r1 = simulate_fleet("easycrash", cfg1, PROFILE)
    assert r1.latency_mean > r0.latency_mean
    # ...and the tax never applies to the checkpoint policy
    c0 = simulate_fleet("checkpoint", cfg0)
    c1 = simulate_fleet("checkpoint", cfg1)
    assert c0 == c1
