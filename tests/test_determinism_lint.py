"""Bitwise-batchability determinism lint: the known-bad vmapped matmul is
flagged, the sanctioned lax.map form passes, and every shipped batched
kernel in the registry is clean."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis import LintFinding, lint_app, lint_batched_fn
from repro.analysis.lint import run_determinism_lint
from repro.hpc.suite import app_names, get_app

A = np.arange(16, dtype=np.float32).reshape(4, 4) / 16.0
U = np.ones((3, 4), np.float32)


def test_vmapped_matmul_flagged():
    """The violation that motivated the lint: vmap turns the per-lane matvec
    into one batched GEMM with a different reduction tiling."""
    findings = lint_batched_fn(
        "bad/vmap_matmul", jax.vmap(lambda u: A @ u), (U,), {0: 0})
    assert findings, "vmapped matmul must be flagged"
    assert any(f.primitive == "dot_general" for f in findings)
    assert all(isinstance(f, LintFinding) for f in findings)


def test_lax_map_matmul_passes():
    """lax.map runs the serial matvec once per lane — bitwise-safe."""
    findings = lint_batched_fn(
        "good/map_matmul", lambda ub: lax.map(lambda u: A @ u, ub), (U,), {0: 0})
    assert findings == []


def test_cross_lane_reduction_flagged():
    findings = lint_batched_fn(
        "bad/cross_lane_sum", lambda ub: jnp.sum(ub, axis=0), (U,), {0: 0})
    assert any(f.primitive == "reduce_sum" and "lane axis" in f.reason
               for f in findings)


def test_per_lane_reduction_passes():
    findings = lint_batched_fn(
        "good/per_lane_sum", lambda ub: jnp.sum(ub, axis=1), (U,), {0: 0})
    assert findings == []


def test_matmul_inside_vmapped_loop_flagged():
    """A vmapped fori_loop is recursed into, not waved through: a matmul in
    its body is still caught."""
    def body(u):
        return lax.fori_loop(0, 3, lambda _, x: jnp.tanh(A @ x), u)

    findings = lint_batched_fn(
        "bad/vmap_loop_matmul", jax.vmap(body), (U,), {0: 0})
    assert any(f.primitive == "dot_general" for f in findings)


def test_vmapped_elementwise_loop_passes():
    """Lane-carrying scan consts/carry from a vmapped loop are fine as long
    as the body stays elementwise per lane."""
    b = np.full((3, 4), 0.5, np.float32)

    def body(u, bb):
        return lax.fori_loop(0, 3, lambda _, x: jnp.tanh(x) + bb, u)

    findings = lint_batched_fn(
        "good/vmap_loop_elementwise", jax.vmap(body), (U, b), {0: 0, 1: 0})
    assert findings == []


def test_all_shipped_batched_apps_pass():
    """Every supports_batched_step app must declare kernels and lint clean —
    the vectorized engine's bitwise contract, enforced statically."""
    checked = 0
    for name in app_names():
        app = get_app(name)
        if not app.supports_batched_step:
            continue
        kernels = app.batched_kernels()
        assert kernels, f"{name}: supports_batched_step but no batched_kernels()"
        for kname, findings in lint_app(app).items():
            assert findings == [], f"{name}/{kname}: {findings}"
            checked += 1
    assert checked >= 5


def test_cli_passes_on_shipped_apps(capsys):
    assert run_determinism_lint() == 0
    out = capsys.readouterr().out
    assert "kernels checked, 0 findings" in out


def test_cli_flags_missing_kernels():
    class FakeApp:
        name = "fake"
        supports_batched_step = True

        @staticmethod
        def batched_kernels():
            return ()

    with pytest.MonkeyPatch.context() as mp:
        import repro.hpc.suite as suite
        mp.setattr(suite, "get_app", lambda name, **kw: FakeApp())
        assert run_determinism_lint(["fake"]) == 1
