"""Crash-campaign behaviour on the HPC suite (CI problem sizes)."""
import numpy as np
import pytest

from repro.core import CacheConfig, CrashTester, PersistPlan
from repro.core.workflow import run_workflow
from repro.hpc.suite import ci_app, default_cache


@pytest.fixture(scope="module")
def mg_setup():
    app = ci_app("mg")
    return app, default_cache(app)


def test_golden_run_verifies(mg_setup):
    app, cache = mg_setup
    tester = CrashTester(app, PersistPlan.none(), cache)
    assert tester.golden_iters > 0


def test_campaign_classes_partition(mg_setup):
    app, cache = mg_setup
    camp = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(12)
    fr = camp.class_fractions()
    assert sum(fr.values()) == pytest.approx(1.0)
    assert all(r.outcome in ("S1", "S2", "S3", "S4") for r in camp.records)
    assert all(0.0 <= v <= 1.0 for r in camp.records for v in r.inconsistency.values())


def test_persistence_never_hurts_mg(mg_setup):
    """Flushing the critical object at loop end must not reduce
    recomputability (and, for MG, should improve it)."""
    app, cache = mg_setup
    base = CrashTester(app, PersistPlan.none(), cache, seed=0).run_campaign(30)
    plan = PersistPlan.at_loop_end(("u",), app)
    ec = CrashTester(app, plan, cache, seed=0).run_campaign(30)
    assert ec.recomputability >= base.recomputability


def test_flushed_object_has_lower_inconsistency(mg_setup):
    app, cache = mg_setup
    base = CrashTester(app, PersistPlan.none(), cache, seed=1).run_campaign(25)
    plan = PersistPlan.best(("u",), app)
    ec = CrashTester(app, plan, cache, seed=1).run_campaign(25)
    mean_u = lambda c: np.mean([r.inconsistency["u"] for r in c.records])
    assert mean_u(ec) <= mean_u(base) + 1e-9


def test_montecarlo_strict_verification():
    """The EP-like negative control: mid-accumulate crashes cannot pass the
    exact-tally acceptance, flushing the tallies fixes it."""
    app = ci_app("montecarlo")
    cache = default_cache(app)
    base = CrashTester(app, PersistPlan.none(), cache, seed=0).run_campaign(30)
    plan = PersistPlan(objects=("counts", "sums"), region_freq={1: 1})
    ec = CrashTester(app, plan, cache, seed=0).run_campaign(30)
    assert ec.recomputability >= base.recomputability
    assert ec.recomputability > 0.9


def test_cg_reports_extra_iterations():
    app = ci_app("cg")
    cache = default_cache(app)
    camp = CrashTester(app, PersistPlan.none(), cache, seed=2).run_campaign(25)
    s2 = [r for r in camp.records if r.outcome == "S2"]
    if s2:  # CG's fragile recurrence typically needs extra iterations
        assert all(r.extra_iters >= 1 for r in s2)


def test_workflow_end_to_end():
    app = ci_app("kmeans")
    cache = default_cache(app)
    wf = run_workflow(app, n_tests=40, cache=cache, seed=0)
    assert wf.critical  # at least one critical object found
    assert "centroids" in wf.critical
    assert wf.region_selection.total_overhead <= wf.t_s + 1e-9
    # validation: the selected plan improves on the baseline
    val = CrashTester(app, wf.plan, cache, seed=9).run_campaign(40)
    assert val.recomputability >= wf.baseline_campaign.recomputability
