"""Fault-model subsystem: determinism, worker parity, resume, model semantics.

Every :class:`~repro.core.faults.FaultModel` must be bit-for-bit identical
across worker counts and across a kill/resume through the campaign store —
the engine's determinism contract does not bend for exotic failure flavors.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import CrashTester, PersistPlan
from repro.core.campaign_store import CampaignStoreError
from repro.core.cache_sim import (
    CacheConfig,
    RegionEvents,
    Sweep,
    TornBlock,
    apply_torn_blocks,
    resolve_nvm_image,
    resolve_window_images,
    simulate_window,
)
from repro.core.crash_tester import PlannedTest
from repro.core.faults import (
    FAULT_MODELS,
    BitFlip,
    CorrelatedRegion,
    MultiCrash,
    PowerFail,
    TornWrite,
    fault_model_from_spec,
    get_fault_model,
)
from repro.hpc.suite import ci_app, default_cache

ALL_MODELS = [
    PowerFail(),
    TornWrite(),
    MultiCrash(),
    BitFlip(),
    CorrelatedRegion(),
]
_IDS = [m.model_name for m in ALL_MODELS]


@pytest.fixture(scope="module")
def km_setup():
    app = ci_app("kmeans")
    return app, default_cache(app)


def _dicts(campaign):
    return [dataclasses.asdict(r) for r in campaign.records]


# -------------------------------------------------------------------- registry
def test_registry_and_spec_round_trip():
    assert set(FAULT_MODELS) == {
        "power-fail", "torn-write", "multi-crash", "bit-flip",
        "correlated-region",
    }
    for model in ALL_MODELS:
        spec = model.spec()
        assert spec["model"] == model.model_name
        import json

        assert json.loads(json.dumps(spec)) == spec  # store fingerprint safe
        assert fault_model_from_spec(spec) == model
    with pytest.raises(KeyError, match="unknown fault model"):
        get_fault_model("meteor-strike")


def test_app_fault_defaults_layering(km_setup):
    sor = ci_app("sor")
    m = get_fault_model("torn-write", app=sor)
    assert (m.p_torn, m.depth) == (0.7, 16)          # sor's fault_defaults
    m = get_fault_model("torn-write", app=sor, depth=3)
    assert (m.p_torn, m.depth) == (0.7, 3)           # explicit override wins
    app, _ = km_setup
    assert get_fault_model("torn-write", app=app) == TornWrite()


# ----------------------------------------------------- PowerFail compatibility
def test_powerfail_planning_is_the_historical_stream(km_setup):
    """The default model must consume the campaign RNG exactly like the
    pre-fault-model engine: two draws per test, no fault entropy."""
    app, cache = km_setup
    tester = CrashTester(app, PersistPlan.none(), cache, seed=11)
    tests = tester.plan_campaign(16, 11)
    rng = np.random.default_rng(11)
    for pt in tests:
        crash_iter = int(rng.integers(0, tester.golden_iters))
        t_lo, t_end = tester.window_bounds(crash_iter)
        crash_t = int(rng.integers(t_lo, t_end))
        assert (pt.crash_iter, pt.crash_t, pt.fault_seed) == (crash_iter, crash_t, 0)


def test_default_fault_is_powerfail(km_setup):
    app, cache = km_setup
    assert CrashTester(app, PersistPlan.none(), cache).fault == PowerFail()


# ---------------------------------------------------------------- determinism
@pytest.mark.parametrize("model", ALL_MODELS, ids=_IDS)
def test_campaign_deterministic(km_setup, model):
    app, cache = km_setup
    a = CrashTester(app, PersistPlan.none(), cache, seed=5, fault=model).run_campaign(8)
    b = CrashTester(app, PersistPlan.none(), cache, seed=5, fault=model).run_campaign(8)
    assert _dicts(a) == _dicts(b)


@pytest.mark.slow
@pytest.mark.parametrize("model", ALL_MODELS, ids=_IDS)
def test_worker_parity(km_setup, model):
    """Bit-for-bit identical outcomes for n_workers in {1, 2, 4}."""
    app, cache = km_setup
    serial = CrashTester(app, PersistPlan.none(), cache, seed=5, fault=model).run_campaign(10)
    for workers in (2, 4):
        par = CrashTester(app, PersistPlan.none(), cache, seed=5, fault=model).run_campaign(
            10, n_workers=workers
        )
        assert _dicts(par) == _dicts(serial), (model.model_name, workers)


@pytest.mark.parametrize("model", ALL_MODELS, ids=_IDS)
def test_resume_after_kill(km_setup, tmp_path, model):
    """A killed campaign (torn trailing shard line) resumes to the full
    result, executing only the missing shards."""
    app, cache = km_setup
    path = str(tmp_path / f"{model.model_name}.jsonl")
    full = CrashTester(app, PersistPlan.none(), cache, seed=5, fault=model).run_campaign(
        10, store_path=path
    )
    lines = open(path).read().splitlines()
    assert len(lines) >= 3  # header + >= 2 shards
    with open(path, "w") as f:
        f.write("\n".join(lines[:2]) + "\n" + lines[2][: len(lines[2]) // 2])
    resumed = CrashTester(app, PersistPlan.none(), cache, seed=5, fault=model).run_campaign(
        10, store_path=path
    )
    assert _dicts(resumed) == _dicts(full)


def test_store_refuses_different_fault_model(km_setup, tmp_path):
    app, cache = km_setup
    path = str(tmp_path / "campaign.jsonl")
    CrashTester(app, PersistPlan.none(), cache, seed=5).run_campaign(
        6, store_path=path
    )
    with pytest.raises(CampaignStoreError):
        CrashTester(
            app, PersistPlan.none(), cache, seed=5, fault=TornWrite()
        ).run_campaign(6, store_path=path)
    # different parameters of the same model are different campaigns too
    path2 = str(tmp_path / "torn.jsonl")
    CrashTester(
        app, PersistPlan.none(), cache, seed=5, fault=TornWrite()
    ).run_campaign(6, store_path=path2)
    with pytest.raises(CampaignStoreError):
        CrashTester(
            app, PersistPlan.none(), cache, seed=5, fault=TornWrite(p_torn=0.9)
        ).run_campaign(6, store_path=path2)


def test_legacy_store_without_fault_key_resumes_as_powerfail(km_setup, tmp_path):
    """Stores written before fault models existed ran under power-fail
    semantics: they must stay resumable with the default model and still
    refuse any other."""
    import json

    app, cache = km_setup
    path = str(tmp_path / "legacy.jsonl")
    full = CrashTester(app, PersistPlan.none(), cache, seed=5).run_campaign(
        6, store_path=path
    )
    lines = open(path).read().splitlines()
    header = json.loads(lines[0])
    del header["fault"]  # a PR-1 header has no fault key
    with open(path, "w") as f:
        f.write("\n".join([json.dumps(header)] + lines[1:]) + "\n")
    resumed = CrashTester(app, PersistPlan.none(), cache, seed=5).run_campaign(
        6, store_path=path
    )
    assert _dicts(resumed) == _dicts(full)
    with pytest.raises(CampaignStoreError):
        CrashTester(
            app, PersistPlan.none(), cache, seed=5, fault=TornWrite()
        ).run_campaign(6, store_path=path)


# ------------------------------------------------------------------ torn-write
def _one_sweep_window(n_blocks=10, block_bytes=16, capacity=32):
    objs = {"a": n_blocks}
    regions = [RegionEvents(seq=0, iter_idx=0, region_idx=0,
                            events=(Sweep("a", write=True),))]
    trace = simulate_window(CacheConfig(capacity, block_bytes), objs, regions)
    start = {"a": np.zeros(n_blocks * block_bytes // 4, np.float32)}
    seq_values = {0: {"a": np.ones(n_blocks * block_bytes // 4, np.float32)}}
    return trace, start, seq_values, block_bytes


def test_tearing_hook_lands_partial_cachelines():
    """A torn block's prefix takes the in-flight version, its suffix keeps
    the resolved NVM value; other crashes in the batch are unaffected."""
    trace, start, seq_values, bb = _one_sweep_window()
    crash_t = 5  # mid-sweep: blocks 0-4 written, all still dirty in cache
    tearing = [[TornBlock("a", 4, 8, 0)], None]
    nvms, _ = resolve_window_images(
        trace, [crash_t, crash_t], start, seq_values, bb, tearing=tearing
    )
    torn = nvms[0]["a"].view(np.uint8)
    lo = 4 * bb
    ref = resolve_nvm_image(trace, crash_t, start, seq_values, bb)
    np.testing.assert_array_equal(nvms[1]["a"], ref["a"])  # untorn == single-shot
    expect = ref["a"].view(np.uint8).copy()
    expect[lo:lo + 8] = seq_values[0]["a"].view(np.uint8)[lo:lo + 8]
    np.testing.assert_array_equal(torn, expect)


def test_apply_torn_blocks_ignores_unknown_and_clamps():
    trace, start, seq_values, bb = _one_sweep_window(n_blocks=3)
    img = resolve_nvm_image(trace, 1, start, seq_values, bb)
    before = {o: v.copy() for o, v in img.items()}
    apply_torn_blocks(img, [TornBlock("ghost", 0, 8, 0),     # unknown object
                            TornBlock("a", 0, 8, 99),        # unknown writer
                            TornBlock("a", 2, 10_000, 0)],   # cut clamped
                      seq_values, bb)
    np.testing.assert_array_equal(
        img["a"].view(np.uint8)[:2 * bb], before["a"].view(np.uint8)[:2 * bb]
    )
    np.testing.assert_array_equal(
        img["a"].view(np.uint8)[2 * bb:],
        seq_values[0]["a"].view(np.uint8)[2 * bb:],
    )


def test_torn_write_model_tears_only_the_inflight_sweep():
    trace, _, _, bb = _one_sweep_window(n_blocks=10)
    model = TornWrite(p_torn=1.0, depth=4)
    test = PlannedTest(0, 0, 6, fault_seed=123)
    torn = model.torn_blocks(test, trace, bb)
    assert torn  # p=1: every candidate tears
    assert {tb.block for tb in torn} == {2, 3, 4, 5}  # last `depth` stores
    assert all(tb.obj == "a" and 1 <= tb.cut_bytes < bb for tb in torn)
    # crash after the sweep drained: nothing in flight, nothing tears
    assert model.torn_blocks(PlannedTest(0, 0, 10, fault_seed=123), trace, bb) is None
    # decisions depend only on the pre-drawn fault seed
    assert model.torn_blocks(test, trace, bb) == torn


# -------------------------------------------------------------------- bit-flip
def test_bitflip_flips_exactly_k_bits_outside_protected():
    image = {
        "u": np.zeros(64, np.float32),
        "flushed": np.zeros(64, np.float32),
        "k": np.zeros(1, np.int64),
    }
    model = BitFlip(n_bits=12)
    out = model.corrupt_image(PlannedTest(0, 0, 0, fault_seed=7), image,
                              protected=("flushed", "k"))
    assert np.count_nonzero(out["flushed"]) == 0
    assert np.count_nonzero(out["k"]) == 0
    flipped = int(np.unpackbits(out["u"].view(np.uint8)).sum())
    assert flipped == 12  # distinct positions: every flip lands
    # the input image is not mutated in place
    assert np.count_nonzero(image["u"]) == 0
    # protected-everything leaves the image untouched
    same = model.corrupt_image(PlannedTest(0, 0, 0, fault_seed=7), image,
                               protected=tuple(image))
    assert all(np.count_nonzero(v) == 0 for v in same.values())


# ----------------------------------------------------------- correlated-region
class _FakePlanner:
    """Minimal planner surface for exercising draw_crash_point in isolation."""

    golden_iters = 7

    def __init__(self, spans):
        self._spans = spans

    def window_bounds(self, crash_iter):
        t_end = self._spans[-1][1]
        return (t_end, 2 * t_end) if crash_iter >= 1 else (0, t_end)

    def region_time_spans(self):
        return self._spans


def test_correlated_region_concentrates_on_heaviest():
    """With spans (10, 30, 10), the heaviest region holds 60% of the window
    clock; shape=8 weighting concentrates essentially every draw there."""
    planner = _FakePlanner([(0, 10), (10, 40), (40, 50)])
    rng = np.random.default_rng(0)
    model = CorrelatedRegion(shape=8.0)
    hits = 0
    for _ in range(400):
        crash_iter, crash_t = model.draw_crash_point(rng, planner)
        t_lo, t_end = planner.window_bounds(crash_iter)
        assert t_lo <= crash_t < t_end
        off = crash_t - t_lo
        hits += 10 <= off < 40
    assert hits / 400 > 0.99  # (30/10)**8 : 1 odds per light region
    # shape=1 recovers residency-proportional sampling
    rng = np.random.default_rng(0)
    flat_hits = sum(
        10 <= (lambda p: p[1] - planner.window_bounds(p[0])[0])(
            CorrelatedRegion(shape=1.0).draw_crash_point(rng, planner)
        ) < 40
        for _ in range(400)
    )
    assert abs(flat_hits / 400 - 0.6) < 0.08


def test_correlated_region_on_a_real_app(km_setup):
    """End-to-end: planned crash points are valid and lean toward the
    heaviest region at least as hard as the uniform draw does."""
    app, cache = km_setup
    heavy = CrashTester(app, PersistPlan.none(), cache, seed=9,
                        fault=CorrelatedRegion(shape=8.0))
    spans = heavy.region_time_spans()
    heaviest = max(range(len(spans)), key=lambda k: spans[k][1] - spans[k][0])

    def hit_rate(tester):
        tests = tester.plan_campaign(300, 9)
        hits = 0
        for t in tests:
            t_lo, t_end = tester.window_bounds(t.crash_iter)
            assert t_lo <= t.crash_t < t_end
            off = t.crash_t - t_lo
            hits += spans[heaviest][0] <= off < spans[heaviest][1]
        return hits / len(tests)

    uniform = CrashTester(app, PersistPlan.none(), cache, seed=9)
    assert hit_rate(heavy) > hit_rate(uniform)


# ----------------------------------------------------------------- multi-crash
def test_multicrash_recovery_plan_bounds():
    model = MultiCrash()
    for fs in range(50):
        t = PlannedTest(0, 3, 0, fault_seed=fs)
        plan = model.recovery_plan(t, 3, 10)
        assert plan is not None  # p_recrash=1.0
        recrash_iter, u = plan
        assert 3 <= recrash_iter < 10
        assert 0.0 <= u < 1.0
        assert model.recovery_plan(t, 3, 10) == plan  # pure in fault_seed
    assert MultiCrash(p_recrash=0.0).recovery_plan(
        PlannedTest(0, 3, 0, fault_seed=1), 3, 10
    ) is None


def test_multicrash_shifts_outcomes(km_setup):
    """Recovery-from-recovery makes life harder.  ``p_recrash=0`` plans the
    identical campaign (same RNG draws) but never fires the second crash, so
    the comparison isolates the recovery fault itself."""
    app, cache = km_setup
    calm = CrashTester(app, PersistPlan.none(), cache, seed=5,
                       fault=MultiCrash(p_recrash=0.0)).run_campaign(12)
    multi = CrashTester(app, PersistPlan.none(), cache, seed=5,
                        fault=MultiCrash()).run_campaign(12)
    assert [(r.iter_idx, r.region_idx, r.frac) for r in multi.records] == \
           [(r.iter_idx, r.region_idx, r.frac) for r in calm.records]
    assert multi.class_fractions()["S1"] <= calm.class_fractions()["S1"] + 1e-9
    assert _dicts(multi) != _dicts(calm)  # the second crash leaves a mark
