"""Campaign characterization of the model stack: lm-train + decode apps.

The apps themselves live in ``repro.models.train_app`` / ``serve_app``; what
matters here is that they are *first-class suite citizens*: constructible
through the app registry, campaign-characterizable with the same S1–S4
machinery as the HPC suite, worker-count invariant, kill/resume-able through
a shard store, and engine-parity clean ('vec' == 'ref').
"""
import dataclasses as dc
import warnings

import numpy as np
import pytest

from repro.core import (
    CacheConfig,
    CrashTester,
    PersistPlan,
    WorkflowConfig,
    run_workflow,
)
from repro.hpc.suite import app_names, ci_app, default_cache, get_app, register_app


def _dicts(camp):
    return [dc.asdict(r) for r in camp.records]


@pytest.fixture(scope="module")
def lm_setup():
    app = ci_app("lm-train")
    return app, default_cache(app)


@pytest.fixture(scope="module")
def decode_setup():
    app = ci_app("decode")
    return app, default_cache(app)


# -------------------------------------------------------------- app registry
def test_registry_covers_model_stack():
    names = app_names()
    for name in ("lm-train", "decode", "mg", "cg", "pagerank"):
        assert name in names
    app = get_app("lm-train", n_iters=4, batch=2, seq=8, width=32)
    assert app.name == "lm-train"
    assert get_app("decode", n_iters=4, batch=1, prompt_len=4, width=32).name == "decode"


def test_registry_unknown_name_lists_available():
    with pytest.raises(KeyError, match="lm-train"):
        get_app("no-such-app")


def test_register_app_validates_and_overrides():
    with pytest.raises(TypeError, match="callable"):
        register_app("bad", None)
    sentinel = ci_app("mg")
    register_app("custom-mg", lambda **kw: sentinel)
    try:
        assert get_app("custom-mg") is sentinel
        assert "custom-mg" in app_names()
    finally:
        from repro.hpc.suite import _APP_FACTORIES

        del _APP_FACTORIES["custom-mg"]


def test_fault_defaults_present_on_model_apps(lm_setup, decode_setup):
    for app, _ in (lm_setup, decode_setup):
        assert "bit-flip" in app.fault_defaults
        assert "correlated-region" in app.fault_defaults


# ----------------------------------------------------------------- lm-train
def test_lm_train_campaign_classes_partition(lm_setup):
    app, cache = lm_setup
    camp = CrashTester(app, PersistPlan.none(), cache, seed=0).run_campaign(10)
    f = camp.class_fractions()
    assert set(f) == {"S1", "S2", "S3", "S4"}
    assert abs(sum(f.values()) - 1.0) < 1e-9
    assert len(camp.records) == 10


def test_lm_train_worker_parity(lm_setup):
    """n_workers in {1, 2} must give identical campaigns.  The app's payload
    carries jitted closures (not picklable), so 2 workers falls back to the
    serial path with a warning — same results, by construction."""
    app, cache = lm_setup
    serial = CrashTester(app, PersistPlan.none(), cache, seed=1).run_campaign(
        6, n_workers=1
    )
    with pytest.warns(RuntimeWarning, match="not picklable"):
        fanned = CrashTester(app, PersistPlan.none(), cache, seed=1).run_campaign(
            6, n_workers=2
        )
    assert _dicts(fanned) == _dicts(serial)


def test_lm_train_kill_resume(lm_setup, tmp_path):
    """A killed lm-train campaign resumes from its shard store to results
    identical to an uninterrupted run."""
    app, cache = lm_setup
    path = str(tmp_path / "lm_campaign.jsonl")
    full = CrashTester(app, PersistPlan.none(), cache, seed=2).run_campaign(
        8, store_path=path
    )
    lines = open(path).read().splitlines()
    assert len(lines) >= 4  # header + >= 3 shards
    # kill mid-run: header + one complete shard + a torn append
    with open(path, "w") as f:
        f.write("\n".join(lines[:2]) + "\n" + lines[2][: len(lines[2]) // 2])
    resumed = CrashTester(app, PersistPlan.none(), cache, seed=2).run_campaign(
        8, store_path=path
    )
    assert _dicts(resumed) == _dicts(full)


def test_lm_train_engine_parity(lm_setup):
    """'vec' and 'ref' campaign engines are bit-for-bit identical on the
    batched-step training app (the lax.map + per-lane-numpy contract)."""
    app, cache = lm_setup
    assert app.supports_batched_step
    vec = CrashTester(app, PersistPlan.none(), cache, seed=3, engine="vec").run_campaign(8)
    ref = CrashTester(app, PersistPlan.none(), cache, seed=3, engine="ref").run_campaign(8)
    assert _dicts(vec) == _dicts(ref)


def test_lm_train_persisting_params_never_hurts(lm_setup):
    app, cache = lm_setup
    base = CrashTester(app, PersistPlan.none(), cache, seed=4).run_campaign(10)
    ec = CrashTester(
        app, PersistPlan.at_loop_end(("params",), app), cache, seed=4
    ).run_campaign(10)
    assert ec.recomputability >= base.recomputability


@pytest.mark.slow
def test_lm_train_workflow_end_to_end(lm_setup, tmp_path):
    """The full paper workflow on LM training: S1–S4 rates, object selection,
    a knapsack plan under (t_s, tau), and a fingerprinted plan artifact."""
    from repro.core import load_plan, save_plan

    app, cache = lm_setup
    wf = run_workflow(app, WorkflowConfig(n_tests=20, cache=cache, seed=0))
    f = wf.baseline_campaign.class_fractions()
    assert abs(sum(f.values()) - 1.0) < 1e-9
    assert wf.region_selection.total_overhead <= wf.t_s + 1e-9
    assert set(wf.plan.objects) <= set(app.candidates)
    path = str(tmp_path / "lm_plan.json")
    save_plan(path, wf.plan, app.name, cache=cache)
    art = load_plan(path)
    assert art.app_name == "lm-train"


# -------------------------------------------------------------------- decode
def test_decode_app_iterates_and_verifies(decode_setup):
    app, _ = decode_setup
    s = app.init(0)
    for _ in range(app.n_iters):
        s = app.run_iteration(s)
    v = app.verify(s)
    assert v.passed and v.metric == 1.0
    # committed stream is fully populated past the prompt
    toks = np.asarray(s["tokens"])
    assert int(s["k"][0]) == app.n_iters
    assert toks.shape == (app.batch, app.prompt_len + app.n_iters + 1)


def test_decode_divergence_bounded_not_exact(decode_setup):
    """The decode acceptance test is prefix/token match, not bitwise state:
    a perturbed cache must still verify when divergence stays in band."""
    app, _ = decode_setup
    s = app.init(0)
    for _ in range(app.n_iters):
        s = app.run_iteration(s)
    perturbed = dict(s)
    toks = np.array(perturbed["tokens"], copy=True)
    toks[0, -1] += 1  # one diverged token out of batch*(n_iters+1)
    perturbed["tokens"] = toks
    v = app.verify(perturbed)
    assert v.metric < 1.0
    assert v.passed  # bounded divergence is acceptable...
    app_strict = ci_app("decode", match_frac=1.0)
    assert not app_strict.verify(perturbed).passed  # ...unless the band is 0


def test_decode_campaign_classes_partition(decode_setup):
    app, cache = decode_setup
    camp = CrashTester(app, PersistPlan.none(), cache, seed=0).run_campaign(10)
    f = camp.class_fractions()
    assert abs(sum(f.values()) - 1.0) < 1e-9
    assert len(camp.records) == 10


@pytest.mark.slow
def test_decode_workflow_end_to_end(decode_setup, tmp_path):
    from repro.core import load_plan, save_plan

    app, cache = decode_setup
    wf = run_workflow(app, WorkflowConfig(n_tests=16, cache=cache, seed=0))
    f = wf.baseline_campaign.class_fractions()
    assert abs(sum(f.values()) - 1.0) < 1e-9
    assert set(wf.plan.objects) <= set(app.candidates)
    path = str(tmp_path / "decode_plan.json")
    save_plan(path, wf.plan, app.name, cache=cache)
    assert load_plan(path).app_name == "decode"
