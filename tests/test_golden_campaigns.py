"""Golden regression: pinned S1-S4 counts for tiny fixed-seed campaigns.

Any engine change that silently shifts outcome classification — cache-model
semantics, window resolution, planning RNG, restart bookkeeping — fails
here loudly, per suite app.  The counts live in
``tests/golden/campaign_goldens.json``; when a shift is *intended* (and
bit-for-bit compatibility has been consciously given up), regenerate with

    PYTHONPATH=src python tests/test_golden_campaigns.py --regen

and say so in the commit message.
"""
import json
import os

import pytest

from repro.core import CrashTester, PersistPlan
from repro.core.faults import get_fault_model
from repro.hpc.suite import CI_SIZES, FAULT_SWEEP_APPS, ci_app, default_cache

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "campaign_goldens.json")

#: campaign geometry of the pinned runs — changing any of this invalidates
#: the golden file (the test compares the stored config too)
GOLDEN_CONFIG = {"n_tests": 8, "seed": 123, "plan": "none"}


def _run_campaign(name, fault_name=None, engine=None):
    app = ci_app(name)
    cache = default_cache(app)
    fault = get_fault_model(fault_name, app=app) if fault_name else None
    camp = CrashTester(
        app, PersistPlan.none(), cache, seed=GOLDEN_CONFIG["seed"], fault=fault,
        engine=engine,
    ).run_campaign(GOLDEN_CONFIG["n_tests"])
    return camp, fault


def _campaign_entry(camp):
    counts = {c: 0 for c in ("S1", "S2", "S3", "S4")}
    for r in camp.records:
        counts[r.outcome] += 1
    return {
        "counts": counts,
        "golden_iters": camp.golden_iters,
        "crash_iters": [r.iter_idx for r in camp.records],
    }


def _profile_entry(camp, fault=None):
    """The campaign's RecomputeProfile as its canonical artifact payload:
    pins the S1–S4 fractions *and* the extra-recompute-iteration histogram
    bins, so profile drift (which would silently shift every downstream
    system-efficiency number) fails loudly."""
    from repro.core.artifacts import profile_to_payload
    from repro.core.sysim import RecomputeProfile

    return profile_to_payload(RecomputeProfile.from_campaign(camp, fault=fault))


def _golden_campaign(name, fault_name=None, engine=None):
    camp, _ = _run_campaign(name, fault_name, engine=engine)
    return _campaign_entry(camp)


def _load_goldens():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def test_campaign_golden_smoke_per_engine():
    """Fast-gate leg: one pinned app through the engine selected by
    ``REPRO_ENGINE`` (CI runs it once per engine).  The slow suite covers
    every app; this asserts the default-engine hot path never drifts from
    the golden classification between scheduled runs."""
    goldens = _load_goldens()
    camp, _ = _run_campaign("sor")
    assert _campaign_entry(camp) == goldens["apps"]["sor"]


@pytest.mark.slow
@pytest.mark.parametrize("engine", ["ref", "vec"])
@pytest.mark.parametrize("name", sorted(CI_SIZES))
def test_campaign_outcomes_match_golden(name, engine):
    goldens = _load_goldens()
    assert goldens["config"] == GOLDEN_CONFIG, (
        "golden config drifted; regenerate tests/golden/campaign_goldens.json"
    )
    assert name in goldens["apps"], f"no golden pinned for {name}; --regen"
    got = _golden_campaign(name, engine=engine)
    want = goldens["apps"][name]
    assert got["golden_iters"] == want["golden_iters"], (
        f"{name}: golden run length changed"
    )
    assert got["crash_iters"] == want["crash_iters"], (
        f"{name}: planned crash points changed (campaign RNG stream drifted)"
    )
    assert got["counts"] == want["counts"], (
        f"{name}[{engine}]: outcome classification shifted: "
        f"{got['counts']} != {want['counts']}"
    )


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(CI_SIZES))
def test_recompute_profile_matches_golden(name):
    """The RecomputeProfile distilled from the pinned campaign — outcome
    fractions, extra-iteration histogram bins, provenance — must reproduce
    exactly: it is the contract between the campaign engine and the
    system-efficiency simulator (repro.core.sysim)."""
    goldens = _load_goldens()
    assert "profiles" in goldens and name in goldens["profiles"], (
        f"no golden RecomputeProfile pinned for {name}; --regen"
    )
    camp, fault = _run_campaign(name)
    got = _profile_entry(camp, fault)
    want = goldens["profiles"][name]
    assert got == want, (
        f"{name}: RecomputeProfile drifted:\n got {got}\nwant {want}"
    )


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(FAULT_SWEEP_APPS))
def test_torn_write_outcomes_match_golden(name):
    """Semantic drift in the fault subsystem (tearing bytes, per-test RNG
    derivation, planning draws) shifts these counts even when the engine
    stays internally consistent."""
    goldens = _load_goldens()
    got = _golden_campaign(name, fault_name="torn-write")
    want = goldens["torn_write_apps"][name]
    assert got["crash_iters"] == want["crash_iters"], (
        f"{name}: torn-write planning stream drifted"
    )
    assert got["counts"] == want["counts"], (
        f"{name}: torn-write classification shifted: "
        f"{got['counts']} != {want['counts']}"
    )


def _regen():
    apps, profiles = {}, {}
    for name in sorted(CI_SIZES):
        camp, fault = _run_campaign(name)
        apps[name] = _campaign_entry(camp)
        profiles[name] = _profile_entry(camp, fault)
    torn = {
        name: _golden_campaign(name, fault_name="torn-write")
        for name in sorted(FAULT_SWEEP_APPS)
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(
            {"config": GOLDEN_CONFIG, "apps": apps,
             "torn_write_apps": torn, "profiles": profiles},
            f, indent=2, sort_keys=True,
        )
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")
    for name, g in apps.items():
        print(f"  {name:12s} {g['counts']}  "
              f"hist={profiles[name]['extra_iters_hist']}")
    for name, g in torn.items():
        print(f"  torn:{name:7s} {g['counts']}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
