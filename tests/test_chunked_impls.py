"""§Perf implementations vs their oracles: chunked attention, chunked RWKV-6,
grouped MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.rwkv6_scan.ref import rwkv6_reference
from repro.models import scaled_down
from repro.models.attention import _attention_chunked
from repro.models.moe import moe_apply, moe_params
from repro.models.rwkv6 import rwkv_chunked_bhtd


@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("chunk", [64, 128])
def test_chunked_attention_matches_ref(window, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (2, 256, 4, 64)) for kk in ks)
    out = _attention_chunked(q, k, v, window=window, chunk=chunk)
    ref = jnp.swapaxes(
        attention_reference(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), window=window), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_chunked_rwkv_matches_ref_realistic_decay(chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    b, h, t, d = 2, 3, 256, 32
    r, k, v = (jax.random.normal(x, (b, h, t, d)) * 0.5 for x in ks[:3])
    # the model's decay parameterization: w = exp(-exp(-6 +- sigma))
    w = jnp.exp(-jnp.exp(-6.0 + 0.5 * jax.random.normal(ks[3], (b, h, t, d))))
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    yc = rwkv_chunked_bhtd(r, k, v, w, u, chunk=chunk)
    yr = rwkv6_reference(r, k, v, w, u)
    rel = float(jnp.max(jnp.abs(yc - yr)) / jnp.max(jnp.abs(yr)))
    assert rel < 2e-2, rel


def test_grouped_moe_matches_ungrouped():
    cfg = scaled_down(get_arch("qwen2-moe-a2.7b"))
    hi = dataclasses.replace(cfg.moe, capacity_factor=8.0, dispatch_groups=1)
    grp = dataclasses.replace(cfg.moe, capacity_factor=8.0, dispatch_groups=4)
    p = moe_params(cfg, jax.random.PRNGKey(2), 1)
    p1 = jax.tree.map(lambda x: x[0], p)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, cfg.d_model)).astype(jnp.bfloat16)
    y1, _ = moe_apply(p1, x, dataclasses.replace(cfg, moe=hi))
    y2, _ = moe_apply(p1, x, dataclasses.replace(cfg, moe=grp))
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32), atol=1e-2
    )
