"""End-to-end driver: train a ~small LM for a few hundred steps under
injected failures, recovering via EasyCrash (arena) with checkpoint fallback.

This drives ``repro.launch.train`` — the same driver that scales to the pod
configs — with failures injected every 60 steps.  Watch the [restore] lines:
recoveries come from the EasyCrash arena (fast path, M''), the loss curve
continues where it left off, and full checkpoints happen at the stretched
Young interval.

Usage:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workdir", default="/tmp/repro_example_train")
    args = ap.parse_args()
    shutil.rmtree(args.workdir, ignore_errors=True)
    train_main([
        "--arch", "stablelm-1.6b",
        "--width", "128",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "64",
        "--workdir", args.workdir,
        "--inject-failure-every", "60",
        "--flush-every", "1",
        "--mtbf", "120",
        "--t-chk", "2.0",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
