"""LM training under failures, both halves of the story:

1. *Characterize*: run the paper's crash-campaign workflow on
   :class:`repro.models.train_app.LMTrainApp` (Adam on a reduced
   transformer) — S1–S4 rates, critical-object selection (params critical,
   moments re-warm), a knapsack persist plan, and a fingerprinted plan
   artifact.
2. *Produce*: drive the production trainer (``repro.launch.train``) for a
   few hundred steps with injected failures, recovering via the EasyCrash
   arena (delta-snapshot persistence) with checkpoint fallback.  Watch the
   [restore] lines: recoveries come from the arena (fast path, M''), the
   loss curve continues where it left off.

Usage:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--tests 20]
"""
import argparse
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import WorkflowConfig, run_workflow, save_plan
from repro.hpc.suite import ci_app, default_cache
from repro.launch.train import main as train_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tests", type=int, default=20)
    ap.add_argument("--workdir", default="/tmp/repro_example_train")
    args = ap.parse_args()

    # ---- 1. campaign characterization of the training loop -----------------
    app = ci_app("lm-train")
    cache = default_cache(app)
    print(f"characterizing lm-train: {app.n_iters} Adam steps, "
          f"{app.init(0)['params'].size:,} params (reduced)")
    wf = run_workflow(app, WorkflowConfig(n_tests=args.tests, cache=cache, seed=0))
    print(f"S1-S4 (no persistence): {wf.baseline_campaign.class_fractions()}")
    for s in wf.object_scores:
        flag = " <- critical" if s.critical else ""
        print(f"  {s.name:8s} Rs={s.rs:+.3f} p={s.p_value:.1e}{flag}")
    print(f"plan: flush {wf.critical} at regions "
          f"{dict(sorted(wf.plan.region_freq.items()))}; recomputability "
          f"{wf.baseline_campaign.recomputability:.0%} -> "
          f"{wf.best_campaign.recomputability:.0%} (best)")
    plan_path = os.path.join(tempfile.mkdtemp(prefix="easycrash-"),
                             "lm-train.plan.json")
    fp = save_plan(plan_path, wf.plan, app_name=app.name, cache=cache,
                   meta={"tau": wf.tau, "t_s": wf.t_s})
    print(f"plan artifact: {plan_path} (sha256 {fp[:16]}...)")

    # ---- 2. production: injected failures, arena recovery ------------------
    print("\nproduction trainer: delta persistence + failure every 60 steps")
    shutil.rmtree(args.workdir, ignore_errors=True)
    train_main([
        "--arch", "stablelm-1.6b",
        "--width", "128",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "64",
        "--workdir", args.workdir,
        "--inject-failure-every", "60",
        "--flush-every", "1",
        "--persist-mode", "delta",
        "--mtbf", "120",
        "--t-chk", "2.0",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
