"""Crash campaign on any registered app — by default LM *training* (the
paper's technique applied to the architecture zoo): characterize
recomputability, select critical data objects, and show what must persist.

Apps come from the suite registry (``repro.hpc.suite.get_app``): the seven
HPC kernels plus the model stack (``lm-train``, ``decode``) share one
namespace, one campaign machinery, and one CLI.

Campaigns fan out over processes with ``--workers N`` and checkpoint shard
results to a JSONL store with ``--store PATH``: kill the campaign mid-run,
re-run the same command, and only the missing shards execute (results are
identical to an uninterrupted run, for any worker count).

``--fault-model`` swaps what a "crash" is (repro.core.faults): torn-write
tears in-flight cachelines, multi-crash re-crashes the recovery run,
bit-flip injects silent corruption, correlated-region concentrates failures
in the heaviest code region.  The store fingerprint includes the model, so a
resumed store refuses a different one.

Usage:  PYTHONPATH=src python examples/crash_campaign.py [--app lm-train]
                                                         [--arch rwkv6-3b]
                                                         [--workers 4]
                                                         [--store camp.jsonl]
                                                         [--fault-model torn-write]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_arch
from repro.core import ENGINES, CacheConfig, CrashTester, PersistPlan
from repro.core.faults import FAULT_MODELS, get_fault_model
from repro.core.selection import select_objects
from repro.hpc.suite import CI_SIZES, app_names, get_app


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="lm-train",
                    help="registered app name (HPC suite + model stack)")
    ap.add_argument("--arch", default="stablelm-1.6b",
                    help="base architecture for the model apps "
                         "(lm-train / decode); ignored by the HPC kernels")
    ap.add_argument("--tests", type=int, default=30)
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--workers", type=int, default=1,
                    help="campaign shards fan out over this many processes")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="JSONL shard store; an interrupted campaign resumes "
                         "from it and executes only the missing shards")
    ap.add_argument("--fault-model", default="power-fail",
                    choices=sorted(FAULT_MODELS),
                    help="failure model for the campaign (default: the "
                         "paper's clean power failure)")
    ap.add_argument("--engine", default=None, choices=list(ENGINES),
                    help="campaign hot path: 'vec' (SoA window simulator + "
                         "batched recompute, the default) or 'ref' (the "
                         "historical oracle); results are bit-for-bit "
                         "identical")
    ap.add_argument("--lane-batch", type=int, default=None, metavar="N",
                    help="restart lanes the vec engine stacks per batched-"
                         "recompute dispatch (default: REPRO_LANE_BATCH env "
                         "or 64); results are identical at any value")
    args = ap.parse_args()

    known = app_names()
    if args.app not in known:
        ap.error(f"unknown app {args.app!r}; registered apps: "
                 + ", ".join(sorted(known)))

    kw = dict(CI_SIZES.get(args.app, {}), n_iters=args.iters)
    if args.app in ("lm-train", "decode"):
        kw["base"] = get_arch(args.arch)
    app = get_app(args.app, **kw)
    fault = get_fault_model(args.fault_model, app=app)
    state = app.init(0)
    ws_blocks = sum(v.nbytes // 64 for v in state.values())
    cache = CacheConfig(capacity_blocks=max(8, int(ws_blocks * 0.5)))
    print(f"app={args.app} candidates={app.candidates}; "
          f"cache={cache.capacity_blocks} blocks of {ws_blocks}; "
          f"fault model: {fault.spec()}")

    base = CrashTester(
        app, PersistPlan.none(), cache, seed=0, fault=fault, engine=args.engine,
        lane_batch=args.lane_batch,
    ).run_campaign(args.tests, n_workers=args.workers, store_path=args.store)
    print(f"\nbaseline (no persistence): {base.class_fractions()}")
    print("per-object inconsistency -> recompute correlation (paper §5.1):")
    objs = [c for c in app.candidates if c != app.iterator_object]
    critical = []
    for s in select_objects(base, objs):
        flag = " <- critical" if s.critical else ""
        if s.critical:
            critical.append(s.name)
        print(f"  {s.name:8s} Rs={s.rs:+.3f} p={s.p_value:.1e}{flag}")
    mean_inc = {
        o: float(np.mean([r.inconsistency.get(o, 0) for r in base.records]))
        for o in objs
    }
    print("mean inconsistency rates:", {k: round(v, 3) for k, v in mean_inc.items()})

    persist = tuple(critical) or (objs[0],)
    ec = CrashTester(app, PersistPlan.at_loop_end(persist, app), cache,
                     seed=0, fault=fault, engine=args.engine,
                     lane_batch=args.lane_batch).run_campaign(
                         args.tests, n_workers=args.workers)
    print(f"\npersist {persist} at loop end: {ec.class_fractions()}")
    print(f"recomputability {base.recomputability:.0%} -> {ec.recomputability:.0%}")
    if args.app == "lm-train":
        print("\ntakeaway: SGD/Adam training is a naturally-resilient iterative "
              "method (paper §2.2) — block-stale parameters act as a bounded "
              "perturbation the optimizer absorbs; moments re-warm in a few steps.")


if __name__ == "__main__":
    main()
