"""Quickstart: EasyCrash on a conjugate-gradient solver in ~60 lines.

Runs the full paper pipeline on one app:
  1. golden run + acceptance verification
  2. crash-test campaign without persistence (intrinsic recomputability)
  3. Spearman object selection + knapsack region selection
  4. validation campaign with the selected plan
  5. system-efficiency projection at 100k-node scale
  6. ship the plan as a fingerprinted artifact and replay it from disk

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import CrashTester, SystemConfig, efficiency_with, efficiency_without
from repro.core.artifacts import load_plan, save_plan
from repro.core.workflow import WorkflowConfig, run_workflow
from repro.hpc.suite import ci_app, default_cache


def main() -> None:
    app = ci_app("cg")
    cache = default_cache(app)
    print(f"app={app.name} grid={app.grid} cache={cache.capacity_blocks} blocks")

    # golden run
    state, iters = app.run_golden()
    res = app.verify(state)
    print(f"golden: {iters} iterations, residual={res.metric:.2e}, verified={res.passed}")

    # steps 1-3: characterize, select objects, select regions
    wf = run_workflow(app, WorkflowConfig(n_tests=60, cache=cache, seed=0))
    print("\nSpearman object selection (paper §5.1):")
    for s in wf.object_scores:
        flag = " <- critical" if s.critical else ""
        print(f"  {s.name:10s} Rs={s.rs:+.3f} p={s.p_value:.1e}{flag}")
    print(f"\nknapsack plan (paper §5.2): flush {wf.critical} at regions "
          f"{dict(wf.plan.region_freq)} (region:every-x-iters)")
    print(f"predicted overhead {100*wf.region_selection.total_overhead:.2f}% "
          f"<= t_s={100*wf.t_s:.0f}%; tau={wf.tau:.2f}")

    # step 4: validate
    val = CrashTester(app, wf.plan, cache, seed=99).run_campaign(60)
    print(f"\nrecomputability: baseline {wf.baseline_campaign.recomputability:.0%} "
          f"-> EasyCrash {val.recomputability:.0%} "
          f"(best achievable {wf.best_campaign.recomputability:.0%})")
    print("outcome classes with EasyCrash:", val.class_fractions())

    # what it buys a 100k-node system
    cfg = SystemConfig(mtbf=12 * 3600.0, t_chk=3200.0)
    base = efficiency_without(cfg).efficiency
    ec = efficiency_with(cfg, val.recomputability, t_s=wf.region_selection.total_overhead).efficiency
    print(f"\n100k-node projection (MTBF 12h, T_chk 3200s): "
          f"efficiency {base:.1%} -> {ec:.1%} (+{100*(ec-base):.1f} pts)")

    # step 4 product: the plan travels as a fingerprinted JSON artifact
    # (repro.core.artifacts); production loads it, verification included
    plan_path = os.path.join(tempfile.mkdtemp(prefix="easycrash-"), "cg.plan.json")
    fp = save_plan(plan_path, wf.plan, app_name=app.name, cache=cache,
                   meta={"tau": wf.tau, "t_s": wf.t_s})
    art = load_plan(plan_path)  # raises ArtifactError if tampered/truncated
    assert art.plan == wf.plan
    print(f"plan artifact: {plan_path} (sha256 {fp[:16]}..., "
          f"fault={art.fault_spec['model']})")


if __name__ == "__main__":
    main()
