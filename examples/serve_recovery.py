"""Serving with EasyCrash cache persistence: batched decode, a mid-stream
crash, and session resumption without re-prefill.

Usage:  PYTHONPATH=src python examples/serve_recovery.py
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


def main() -> None:
    workdir = "/tmp/repro_example_serve"
    shutil.rmtree(workdir, ignore_errors=True)
    serve_main([
        "--arch", "stablelm-1.6b",
        "--width", "128",
        "--prompts", "4",
        "--prompt-len", "32",
        "--decode-steps", "48",
        "--flush-every", "4",
        "--workdir", workdir,
        "--inject-failure-at", "24",
    ])


if __name__ == "__main__":
    main()
