"""Decode serving under failures, both halves of the story:

1. *Characterize*: run the paper's crash-campaign workflow on
   :class:`repro.models.serve_app.DecodeApp` — the decode loop as an
   IterativeApp — to measure S1–S4 rates, find which decode state is
   critical (the KV/recurrent cache *is* the session), and ship the
   resulting persist plan as a fingerprinted artifact.
2. *Produce*: drive the production server (``repro.launch.serve``) with
   delta-snapshot persistence, kill it mid-stream, and resume sessions
   without re-running prefill.
3. *Project*: feed the campaign-measured recovery profile and persist
   overhead into the fleet simulator (``repro.core.fleetsim``) — what the
   measured decode loop means for goodput, SLO, and p99 across a replica
   fleet failing at paper-like rates.

Usage:  PYTHONPATH=src python examples/serve_recovery.py [--tests 16]
"""
import argparse
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (
    POLICIES,
    ArrivalProcess,
    FleetConfig,
    PoissonTrace,
    RecomputeProfile,
    ServiceModel,
    SystemConfig,
    WorkflowConfig,
    fleet_frontier,
    run_workflow,
    save_plan,
)
from repro.hpc.suite import ci_app, default_cache
from repro.launch.serve import main as serve_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tests", type=int, default=16)
    args = ap.parse_args()

    # ---- 1. campaign characterization of the decode loop -------------------
    app = ci_app("decode")
    cache = default_cache(app)
    print(f"characterizing decode: batch={app.batch} prompt_len={app.prompt_len} "
          f"steps={app.n_iters} (acceptance: token match >= {app.match_frac})")
    wf = run_workflow(app, WorkflowConfig(n_tests=args.tests, cache=cache, seed=0))
    print(f"S1-S4 (no persistence): {wf.baseline_campaign.class_fractions()}")
    print(f"critical decode state: {wf.critical}")
    print(f"plan: flush at regions {dict(sorted(wf.plan.region_freq.items()))}; "
          f"recomputability {wf.baseline_campaign.recomputability:.0%} -> "
          f"{wf.best_campaign.recomputability:.0%} (best)")
    plan_path = os.path.join(tempfile.mkdtemp(prefix="easycrash-"),
                             "decode.plan.json")
    fp = save_plan(plan_path, wf.plan, app_name=app.name, cache=cache,
                   meta={"tau": wf.tau, "t_s": wf.t_s})
    print(f"plan artifact: {plan_path} (sha256 {fp[:16]}...)")

    # ---- 2. production: delta-persisted decode, killed and resumed ---------
    print("\nproduction server: delta persistence + mid-stream kill/resume")
    workdir = "/tmp/repro_example_serve"
    shutil.rmtree(workdir, ignore_errors=True)
    serve_main([
        "--arch", "stablelm-1.6b",
        "--width", "128",
        "--prompts", "4",
        "--prompt-len", "32",
        "--decode-steps", "48",
        "--flush-every", "4",
        "--persist-mode", "delta",
        "--workdir", workdir,
        "--inject-failure-at", "24",
    ])

    # ---- 3. fleet projection: the measured profile at serving scale --------
    print("\nfleet projection: measured decode profile across 4 replicas")
    profile = RecomputeProfile.from_campaign(wf.best_campaign)
    cfg = FleetConfig(
        n_replicas=4,
        arrival=ArrivalProcess(rate=5.0, amplitude=0.3),
        service=ServiceModel(mean_s=0.5, sigma=0.6, prefill_s=1.5),
        trace=PoissonTrace(mtbf=900.0),
        system=SystemConfig(mtbf=900.0, t_chk=30.0, nvm_restore_time=2.0),
        slo_latency=2.0,
        queue_cap=48,
        horizon=1800.0,
        t_s=wf.t_s,
        seed=0,
    )
    doc = fleet_frontier(cfg, profile)
    print(f"  profile S1-S4: {dict(profile.fractions)} (persist tax "
          f"t_s={wf.t_s:.3f})")
    for policy in POLICIES:
        p = doc["policies"][policy]
        print(f"  {policy:10s} goodput={p['goodput']:.3f}rps "
              f"slo={p['slo_violation_frac']:.3f} "
              f"p99={p['latency_p99']:.2f}s fails={p['n_failures']}")


if __name__ == "__main__":
    main()
