"""The full EasyCrash workflow (paper §5.3) on the shared-pool orchestrator.

One command runs characterize -> select objects -> measure regions -> solve
the knapsack, with every campaign's crash-test shards interleaved on a single
process pool.  The run is killable: with ``--workflow-store`` every completed
shard is durably appended to a JSONL WorkflowStore, and re-running the same
command resumes, executing only the missing shards (results are bit-for-bit
identical to an uninterrupted run, for any worker count).

``--artifact`` writes the product of the workflow — the persist plan plus
selection evidence — as a fingerprinted JSON artifact that
``repro.core.artifacts.replay_plan`` can re-characterize under any fault
model (see ``benchmarks/bench_recomputability.py --robustness-matrix``).

``--kill-after-shards N`` hard-kills the process (``os._exit(137)``) after N
shards have been durably stored — a deterministic stand-in for `kill -9`,
used by the CI resume smoke test.

Usage:  PYTHONPATH=src python examples/workflow_orchestrate.py \
            [--app sor] [--tests 40] [--workers 4] \
            [--workflow-store wf.jsonl] [--artifact plan.json] \
            [--fault-model torn-write] [--region-measure isolated]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from repro.core.artifacts import (
        load_workflow,
        save_plan,
        save_profile,
        save_workflow,
    )
    from repro.core.cache_sim import ENGINES
    from repro.core.campaign_store import WorkflowStore
    from repro.core.faults import FAULT_MODELS, get_fault_model
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import CI_SIZES, ci_app, default_cache

    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="sor", choices=sorted(CI_SIZES))
    ap.add_argument("--tests", type=int, default=40)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--region-measure", default="isolated",
                    choices=("isolated", "paper"))
    ap.add_argument("--fault-model", default="power-fail",
                    choices=sorted(FAULT_MODELS))
    ap.add_argument("--workflow-store", default=None, metavar="PATH",
                    help="JSONL WorkflowStore; an interrupted workflow "
                         "resumes from it, executing only missing shards")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="write the workflow summary (PATH) and persist plan "
                         "(PATH stem + '.plan.json') as fingerprinted JSON")
    ap.add_argument("--kill-after-shards", type=int, default=0, metavar="N",
                    help="os._exit(137) after N durably stored shards "
                         "(simulated kill -9; requires --workflow-store)")
    ap.add_argument("--engine", default=None, choices=list(ENGINES),
                    help="campaign hot path (default vec); bit-for-bit "
                         "identical results either way")
    args = ap.parse_args()
    if args.kill_after_shards and not args.workflow_store:
        ap.error("--kill-after-shards requires --workflow-store (the kill "
                 "fires from the store's shard callback)")

    app = ci_app(args.app)
    cache = default_cache(app)
    fault = get_fault_model(args.fault_model, app=app)

    stored = 0
    if args.workflow_store and os.path.exists(args.workflow_store):
        by_campaign = WorkflowStore(args.workflow_store).completed_shards_by_campaign()
        stored = sum(len(shards) for shards in by_campaign.values())
        print(f"resuming: {stored} shards already in {args.workflow_store}")

    executed = []

    def on_shard(key: str, shard_id: int) -> None:
        executed.append((key, shard_id))
        if args.kill_after_shards and len(executed) >= args.kill_after_shards:
            print(f"[kill] simulated power failure after "
                  f"{len(executed)} shards (last: {key}:{shard_id})")
            sys.stdout.flush()
            os._exit(137)

    wf = run_workflow(app, WorkflowConfig(
        n_tests=args.tests, cache=cache, seed=0,
        region_measure=args.region_measure, n_workers=args.workers,
        fault_model=fault, store_path=args.workflow_store,
        shard_callback=on_shard if args.workflow_store else None,
        engine=args.engine,
    ))

    print(f"\napp={args.app} fault={fault.spec()} workers={args.workers}")
    print(f"shards: {len(executed)} executed this run"
          + (f", {stored} resumed from store" if args.workflow_store else ""))
    print(f"critical objects: {wf.critical}")
    print(f"plan: flush at regions "
          f"{dict(sorted(wf.plan.region_freq.items()))} (region: every-x-iters)")
    for k, v in wf.summary().items():
        print(f"  {k:28s} {v:.4f}")

    if args.artifact:
        fp = save_workflow(args.artifact, wf, fault=fault, cache=cache)
        plan_path = os.path.splitext(args.artifact)[0] + ".plan.json"
        save_plan(plan_path, wf.plan, app_name=app.name, fault=fault,
                  cache=cache,
                  meta={"tau": wf.tau, "t_s": wf.t_s,
                        "expected_recomputability":
                            wf.region_selection.expected_recomputability})
        # the measured S1-S4 rates + recompute-cost histogram, for the
        # system-efficiency simulator (examples/system_efficiency.py)
        profile_path = os.path.splitext(args.artifact)[0] + ".profile.json"
        save_profile(profile_path, wf.recompute_profile(fault=fault),
                     meta={"campaign": "best", "n_tests": args.tests})
        check = load_workflow(args.artifact)  # verifies the fingerprint
        assert check.plan == wf.plan
        print(f"artifacts: {args.artifact} (fingerprint {fp[:16]}...) "
              f"+ {plan_path} + {profile_path}")


if __name__ == "__main__":
    main()
