"""The paper's closing figure, reproduced from stored artifacts: hybrid
checkpoint+EasyCrash vs checkpoint-only system efficiency.

The input is the product of a finished characterization run — either

* a **recompute-profile artifact** (``--profile``), written by
  ``examples/workflow_orchestrate.py --artifact`` or
  ``repro.core.artifacts.save_profile``: campaign-measured S1–S4 rates plus
  the extra-recompute-iteration histogram; or
* a **workflow artifact** (``--workflow``): the S1–S4 fractions of its
  persist-everywhere campaign (no cost histogram — S2 recoveries are then
  priced at the NVM restore cost alone); or
* nothing: a small campaign is run on ``--app`` first, so the example is
  self-contained (``--save-profile`` keeps the measured profile).

For each checkpoint cost the script prints the analytic closed forms
(Eqs. 6–9) next to the discrete-event simulation of the four policies under
a Poisson failure trace — the "up to 24 %, 15 % on average" comparison, with
measured rates instead of an assumed recomputability.

Usage:  PYTHONPATH=src python examples/system_efficiency.py \
            [--profile prof.json | --workflow wf.json] [--app sor]
            [--tests 40] [--failures 4000] [--mtbf-hours 12]
            [--save-profile out.json]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from repro.core import (
        CrashTester,
        PersistPlan,
        PoissonTrace,
        RecomputeProfile,
        SystemConfig,
        efficiency_with,
        efficiency_without,
        load_profile,
        load_workflow,
        profile_from_workflow,
        save_profile,
        simulate_policy,
    )
    from repro.hpc.suite import CI_SIZES, ci_app, default_cache

    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default=None, metavar="PATH",
                    help="recompute-profile artifact to drive the simulator")
    ap.add_argument("--workflow", default=None, metavar="PATH",
                    help="workflow artifact (rates of its 'best' campaign)")
    ap.add_argument("--app", default="sor", choices=sorted(CI_SIZES),
                    help="app to measure when no artifact is given")
    ap.add_argument("--tests", type=int, default=40,
                    help="campaign size when measuring in-process")
    ap.add_argument("--failures", type=int, default=4000,
                    help="failure events per simulated point")
    ap.add_argument("--mtbf-hours", type=float, default=12.0)
    ap.add_argument("--t-s", type=float, default=0.015,
                    help="EasyCrash flush-overhead fraction")
    ap.add_argument("--save-profile", default=None, metavar="PATH",
                    help="write the measured profile as a fingerprinted artifact")
    args = ap.parse_args()
    if args.profile and args.workflow:
        ap.error("--profile and --workflow are mutually exclusive")

    if args.profile:
        art = load_profile(args.profile)
        prof = art.profile
        print(f"profile artifact: {args.profile} "
              f"(app={prof.app_name}, fingerprint {art.fingerprint[:16]}...)")
    elif args.workflow:
        wa = load_workflow(args.workflow)
        prof = profile_from_workflow(wa, which="best")
        print(f"workflow artifact: {args.workflow} (app={wa.app_name}; "
              f"no recompute-cost histogram — S2 priced at NVM restore only)")
    else:
        app = ci_app(args.app)
        cache = default_cache(app)
        plan = PersistPlan.at_loop_end(app.candidates, app)
        print(f"measuring: {args.tests}-test campaign on {args.app} "
              f"(flush {plan.objects} at loop end)...")
        camp = CrashTester(app, plan, cache, seed=0).run_campaign(args.tests)
        prof = RecomputeProfile.from_campaign(camp)

    print(f"rates: S1={prof.fractions.get('S1', 0.0):.2f} "
          f"S2={prof.fractions.get('S2', 0.0):.2f} "
          f"S3={prof.fractions.get('S3', 0.0):.2f} "
          f"S4={prof.fractions.get('S4', 0.0):.2f}  "
          f"(success {prof.success_rate:.2f}, "
          f"mean S2 recompute {prof.mean_extra_iters():.1f} iters)")
    if args.save_profile:
        fp = save_profile(args.save_profile, prof,
                          meta={"source": "system_efficiency example"})
        print(f"profile artifact -> {args.save_profile} "
              f"(fingerprint {fp[:16]}...)")

    mtbf = args.mtbf_hours * 3600.0
    print(f"\nmtbf={args.mtbf_hours:g} h, t_s={args.t_s:g}, "
          f"{args.failures} failure events per point (seeded)")
    header = (f"{'t_chk':>7} | {'analytic':^17} | "
              f"{'simulated (failure trace)':^37} | gain")
    print(header)
    print(f"{'':>7} | {'C/R':>7} {'EC+C/R':>8} | "
          f"{'none':>7} {'ckpt':>7} {'easycr':>7} {'hybrid':>7} "
          f"{'':>4} | hyb-ckpt")
    print("-" * len(header))
    gains = []
    for t_chk in (32.0, 320.0, 3200.0):
        cfg = SystemConfig(mtbf=mtbf, t_chk=t_chk)
        trace = PoissonTrace(cfg.mtbf)
        base = efficiency_without(cfg).efficiency
        ec = efficiency_with(cfg, prof.recomputability, t_s=args.t_s).efficiency
        sim = {
            policy: simulate_policy(policy, cfg, trace, prof,
                                    n_failures=args.failures,
                                    t_s=args.t_s, seed=7).efficiency
            for policy in ("none", "checkpoint", "easycrash", "hybrid")
        }
        gain = 100 * (sim["hybrid"] - sim["checkpoint"])
        gains.append(gain)
        print(f"{int(t_chk):>6}s | {base:>7.4f} {ec:>8.4f} | "
              f"{sim['none']:>7.4f} {sim['checkpoint']:>7.4f} "
              f"{sim['easycrash']:>7.4f} {sim['hybrid']:>7.4f}      | "
              f"{gain:+5.1f} pts")
    print(f"\nhybrid over checkpoint-only: up to {max(gains):.1f} pts, "
          f"{sum(gains) / len(gains):.1f} on average "
          f"(paper: up to 24, 15 on average)")


if __name__ == "__main__":
    main()
